"""The self-healing layer's units (repro.resilience, DESIGN.md §14).

GradScreen / DivergenceDetector / SentinelPolicy vet gradients and
trajectories deterministically; wrap_step_sentinel fuses the same screen
into a jitted mesh step without touching an accepted trajectory; Supervisor
+ LeaseTable implement the RUNNING -> DOWN -> RESPAWNED/EVICTED machine with
capped jittered backoff (driven here via poll(now=...), no wall clock); the
spec validates every resilience knob at construction; and the chief's
close() names wedged connection threads instead of leaking them. End-to-end
fault runs live in tests/test_chaos.py.
"""
import threading
import time

import numpy as np
import pytest

from repro.engine import ExperimentSpec
from repro.resilience import (
    DivergenceDetector,
    GradScreen,
    LeaseTable,
    SentinelPolicy,
    Supervisor,
    wrap_step_sentinel,
)
from repro.resilience.sentinel import NORM_WARMUP


def _policy(**kw):
    base = dict(level="full", factor=10.0, quarantine_steps=100,
                quarantine_after=2)
    base.update(kw)
    return SentinelPolicy(**base)


# -------------------------------------------------------------- GradScreen


def test_screen_accepts_finite_gradients():
    s = GradScreen(_policy())
    for v in range(5):
        assert s.admit(0, np.ones(3) * (v + 1), v) is None
    c = s.counters()
    assert c["rejections"] == 0 and c["quarantines"] == 0


def test_screen_rejects_non_finite_and_counts_reason():
    s = GradScreen(_policy(quarantine_after=99))
    assert s.admit(0, np.array([1.0, np.nan]), 0) == "non-finite"
    assert s.admit(1, np.array([np.inf, 0.0]), 1) == "non-finite"
    c = s.counters()
    assert c["rejections"] == 2
    assert c["rejections_by_worker"] == {0: 1, 1: 1}
    assert c["rejection_reasons"] == {"non-finite": 2}


def test_consecutive_rejections_quarantine_the_worker():
    s = GradScreen(_policy(quarantine_after=2, quarantine_steps=50))
    s.admit(0, np.array([np.nan]), 0)
    s.admit(0, np.array([np.nan]), 1)          # second in a row -> quarantine
    assert s.counters()["quarantines"] == 1
    assert s.admit(0, np.ones(1), 5) == "quarantined"   # even a sane push
    assert s.admit(0, np.ones(1), 1 + 50) is None       # ban lifts by version
    # an accept in between resets the streak: no quarantine
    s2 = GradScreen(_policy(quarantine_after=2, quarantine_steps=50))
    s2.admit(1, np.array([np.nan]), 0)
    s2.admit(1, np.ones(1), 1)
    s2.admit(1, np.array([np.nan]), 2)
    assert s2.counters()["quarantines"] == 0


def test_norm_screen_trips_only_after_warmup_and_only_at_full():
    s = GradScreen(_policy(level="full", factor=10.0))
    g = np.ones(4)                              # norm 2.0
    for v in range(NORM_WARMUP):
        assert s.admit(0, g, v) is None
    assert s.admit(0, g * 1e6, NORM_WARMUP) == "norm-exploded"
    assert s.admit(0, g * 1.5, NORM_WARMUP + 1) is None  # near the EMA: fine
    # level "finite" has no norm screen: the same explosion sails through
    s2 = GradScreen(_policy(level="finite"))
    for v in range(NORM_WARMUP + 1):
        assert s2.admit(0, g, v) is None
    assert s2.admit(0, g * 1e6, NORM_WARMUP + 2) is None


def test_quarantine_steps_zero_never_bans():
    s = GradScreen(_policy(quarantine_after=1, quarantine_steps=0))
    s.admit(0, np.array([np.nan]), 0)
    assert s.counters()["quarantines"] == 0
    assert s.admit(0, np.ones(1), 1) is None


# ------------------------------------------------------ DivergenceDetector


def test_detector_trips_on_non_finite_and_spikes():
    d = DivergenceDetector(factor=10.0)
    assert not d.update(0.7)
    assert not d.update(0.5)                 # best tracks the minimum
    assert not d.update(4.9)                 # < 10 x 0.5: tolerated wobble
    assert d.update(5.1)                     # > 10 x best: diverged
    assert d.update(float("nan"))
    assert d.update(float("inf"))
    assert d.best == 0.5                     # a diverged sample never updates best


def test_policy_from_spec_round_trips_the_knobs():
    spec = ExperimentSpec(backend="dist", dist_mode="live", mode="asgd",
                          sentinel="full", sentinel_factor=7.0, rollback=True,
                          max_rollbacks=2, lr_backoff=0.25,
                          quarantine_steps=40, quarantine_after=4)
    p = SentinelPolicy.from_spec(spec)
    assert (p.level, p.factor, p.rollback) == ("full", 7.0, True)
    assert (p.max_rollbacks, p.lr_backoff) == (2, 0.25)
    assert (p.quarantine_steps, p.quarantine_after) == (40, 4)
    assert p.screening and p.norm_screen
    assert not SentinelPolicy(level="").screening
    assert not SentinelPolicy(level="finite").norm_screen


# ------------------------------------------------------ wrap_step_sentinel


def test_mesh_sentinel_keeps_the_previous_carry_on_a_bad_step():
    import jax.numpy as jnp

    def step(params, gstate, batch):
        return params + 1.0, gstate + 1.0, {"loss": batch.sum()}

    guarded = wrap_step_sentinel(step, "finite", 10.0)
    p, g, m = guarded(jnp.zeros(2), jnp.zeros(1), jnp.array([1.0]))
    assert int(m["rejected"]) == 0
    np.testing.assert_array_equal(np.asarray(p), 1.0)
    p2, g2, m2 = guarded(p, g, jnp.array([jnp.nan]))   # NaN loss -> rejected
    assert int(m2["rejected"]) == 1
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(p))
    np.testing.assert_array_equal(np.asarray(g2), np.asarray(g))


def test_mesh_sentinel_full_rejects_spikes_and_bad_leaves():
    import collections

    import jax.numpy as jnp

    GS = collections.namedtuple("GS", ["prev_avg_loss", "x"])

    def step(params, gstate, batch):
        return params * batch, gstate._replace(x=gstate.x + 1), \
            {"loss": jnp.abs(batch.sum())}

    guarded = wrap_step_sentinel(step, "full", 10.0)
    gs = GS(prev_avg_loss=jnp.float32(1.0), x=jnp.zeros(1))
    # loss 100 > 10 x prev_avg_loss 1.0 -> spike rejection
    p, g, m = guarded(jnp.ones(2), gs, jnp.array([50.0, 50.0]))
    assert int(m["rejected"]) == 1
    np.testing.assert_array_equal(np.asarray(p), 1.0)
    # sane loss but a non-finite updated leaf -> rejected at "full"
    p, g, m = guarded(jnp.array([1.0, np.inf]), gs, jnp.array([2.0, 0.0]))
    assert int(m["rejected"]) == 1
    # inf prev_avg_loss (the GuidedState init) passes the first sane steps
    gs0 = GS(prev_avg_loss=jnp.float32(np.inf), x=jnp.zeros(1))
    p, g, m = guarded(jnp.ones(2), gs0, jnp.array([2.0, 0.0]))
    assert int(m["rejected"]) == 0
    np.testing.assert_array_equal(np.asarray(p), np.asarray([2.0, 0.0]))


TINY = (("n_layers", 1), ("d_model", 16), ("d_ff", 32), ("vocab_size", 128),
        ("n_heads", 2), ("n_kv_heads", 2))


def _mesh_spec(**kw):
    base = dict(backend="mesh", arch="yi_9b", reduced=True, mode="ssgd",
                strategy="guided_fused", rho=3, staleness=2, lr=5e-2, seed=0,
                steps=6, seq_len=8, global_batch=4, workers=2,
                model_overrides=TINY)
    base.update(kw)
    return ExperimentSpec(**base)


def test_mesh_sentinel_is_bit_exact_on_a_clean_run():
    """Arming the sentinel must not perturb a healthy trajectory: jnp.where
    with an all-true keep is the identity, leaf for leaf."""
    import jax

    from repro.engine import Trainer

    off = Trainer.from_spec(_mesh_spec()).fit()
    on = Trainer.from_spec(_mesh_spec(sentinel="finite")).fit()
    for a, b in zip(jax.tree.leaves(off.model), jax.tree.leaves(on.model)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert on.resilience == {"sentinel": "finite", "rejected_steps": 0}
    assert off.resilience == {}


def test_mesh_sentinel_full_keeps_params_finite_through_divergence():
    """lr=5000 on the tiny LM blows up within a few steps; at level 'full'
    every poisoning step is rejected on device (previous carry re-threaded),
    so the final params stay finite — identically under chunked dispatch."""
    import jax

    from repro.engine import Trainer

    diverging = _mesh_spec(lr=5000.0, steps=10, sentinel="full")
    r = Trainer.from_spec(diverging).fit()
    assert r.resilience["rejected_steps"] >= 1
    assert all(np.all(np.isfinite(np.asarray(x)))
               for x in jax.tree.leaves(r.model))
    r2 = Trainer.from_spec(diverging.replace(chunk_steps=4)).fit()
    assert r2.resilience["rejected_steps"] == r.resilience["rejected_steps"]
    for a, b in zip(jax.tree.leaves(r.model), jax.tree.leaves(r2.model)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- Supervisor + LeaseTable


def test_lease_table_expiry_and_touch():
    lt = LeaseTable(0.5)
    now = time.monotonic()
    assert not lt.expired(0, now)            # never seen: may be connecting
    lt.touch(0)
    assert not lt.expired(0, time.monotonic())
    assert lt.expired(0, time.monotonic() + 1.0)
    t0 = time.monotonic() - 10.0
    assert lt.touched_since(0, t0)
    assert not lt.touched_since(1, t0)
    lt.drop(0)
    assert not lt.expired(0, time.monotonic() + 1.0)
    assert not LeaseTable(0.0).expired(0, now)   # leases off: never expired


class _FakeProc:
    def __init__(self, wid):
        self.wid = wid
        self.dead = False
        self.kills = 0

    def alive(self):
        return not self.dead

    def kill(self):
        self.dead = True
        self.kills += 1

    def cleanup(self):
        pass

    def stderr_tail(self, n=5):
        return ""


def _sup(**kw):
    spawned = []

    def spawn(wid):
        p = _FakeProc(wid)
        spawned.append(p)
        return p

    kw.setdefault("n_workers", 1)
    kw.setdefault("max_respawns", 2)
    sup = Supervisor(spawn, **kw)
    sup.start()
    sup.stop_polling()   # drive poll(now=...) by hand: deterministic clock
    return sup, spawned


def test_supervisor_respawns_after_backoff_and_records_recovery():
    sup, spawned = _sup()
    spawned[0].dead = True
    t = 100.0
    sup.poll(now=t)                          # death detected, backoff starts
    assert len(spawned) == 1                 # not yet: backoff not elapsed
    sup.poll(now=t)
    assert len(spawned) == 1
    sup.poll(now=t + 10.0)                   # way past any backoff
    assert len(spawned) == 2
    assert sup.stats()["respawns"] == 1
    sup.poll(now=t + 11.0)                   # replacement alive, no leases ->
    st = sup.stats()                         # healthy immediately
    assert len(st["recoveries"]) == 1
    assert st["recoveries"][0][0] == 0
    sup.close()


def test_supervisor_evicts_after_respawn_budget():
    sup, spawned = _sup(max_respawns=0)
    spawned[0].dead = True
    sup.poll(now=50.0)                       # streak 1 > budget 0: evicted
    sup.poll(now=500.0)
    assert len(spawned) == 1                 # never respawned
    assert sup.stats()["evicted"] == [0]
    sup.close()


def test_supervisor_backoff_is_capped_and_jittered():
    sup, _ = _sup(backoff_base=0.05, backoff_cap=1.0)
    b1 = sup._backoff(1)
    assert 0.05 <= b1 <= 0.10                # base x (1..2) full jitter
    assert sup._backoff(20) <= 2.0           # capped at cap x 2
    assert sup._backoff(3) >= sup._backoff(1) / 2   # grows (modulo jitter)
    sup.close()


def test_supervisor_lease_expiry_converts_hang_to_death():
    lt = LeaseTable(0.5)
    sup, spawned = _sup(leases=lt)
    lt.touch(0)
    sup.poll(now=time.monotonic())           # fresh lease: healthy
    assert spawned[0].kills == 0
    sup.poll(now=time.monotonic() + 5.0)     # expired: hung -> killed
    assert spawned[0].kills == 1
    assert sup.stats()["lease_expiries"] == 1
    sup.close()


def test_respawn_now_is_an_injected_op_not_a_failure():
    sup, spawned = _sup()
    sup.respawn_now(0)
    assert len(spawned) == 2 and spawned[0].dead
    st = sup.stats()
    assert st["respawns"] == 1 and st["evicted"] == []
    sup.poll(now=1e9)                        # no pending down/heal state
    assert len(spawned) == 2
    sup.close()


def test_supervisor_close_kills_the_fleet():
    sup, spawned = _sup(n_workers=2)
    sup.spawn_extra()
    sup.close()
    assert all(p.dead for p in spawned)
    assert len(sup.procs()) == 3


# ------------------------------------------------------- spec validation


def test_spec_rejects_bad_resilience_knobs():
    live = dict(backend="dist", dist_mode="live", mode="asgd")
    with pytest.raises(ValueError, match="unknown sentinel"):
        ExperimentSpec(sentinel="paranoid", **live)
    with pytest.raises(ValueError, match="sentinel_factor"):
        ExperimentSpec(sentinel="finite", sentinel_factor=1.0, **live)
    with pytest.raises(ValueError, match="neither"):
        ExperimentSpec(backend="scan", sentinel="finite")
    with pytest.raises(ValueError, match="replay"):
        ExperimentSpec(backend="dist", dist_mode="replay", sentinel="finite")
    with pytest.raises(ValueError, match="rollback / quarantine"):
        ExperimentSpec(backend="mesh", sentinel="finite", rollback=True)
    with pytest.raises(ValueError, match="need a sentinel"):
        ExperimentSpec(rollback=True, **live)
    with pytest.raises(ValueError, match="quarantine_after"):
        ExperimentSpec(sentinel="finite", quarantine_after=0, **live)
    with pytest.raises(ValueError, match="lr_backoff"):
        ExperimentSpec(sentinel="finite", rollback=True, lr_backoff=0.0, **live)
    with pytest.raises(ValueError, match="dist_lease_s"):
        ExperimentSpec(dist_lease_s=-1.0, **live)
    # the happy path constructs
    ExperimentSpec(sentinel="full", rollback=True, quarantine_steps=10, **live)


# ------------------------------- chief close() leak report + connect backoff


class _StubStore:
    """Just enough ParameterStore surface for a Chief serving no real run."""

    W = np.zeros(3)

    def __init__(self):
        self.exits = 0
        self.bad = 0

    def record_worker_exit(self):
        self.exits += 1

    def record_bad_frame(self, wid, exc):
        self.bad += 1

    def record_join(self):
        pass

    def progress(self):
        return 0


def test_chief_close_names_wedged_connection_threads():
    from repro.dist import protocol
    from repro.dist.chief import Chief

    store = _StubStore()
    chief = Chief(store, {"n_workers": 1})
    conn = protocol.connect(chief.address)
    conn.send(("hello", 0))
    assert conn.recv()[0] == "welcome"
    # the worker now sits silent: its connection thread is parked in recv()
    with pytest.warns(RuntimeWarning, match="leaked 1 unjoined"):
        chief.close(timeout=0.3)
    assert chief.leaked_threads == ["dist-chief-conn"]
    with pytest.raises(RuntimeError, match="leaked"):
        chief.close(timeout=0.2, strict=True)
    conn.close()             # unwedge: the thread exits via EOF
    for _ in range(100):
        if store.exits == 1 and not any(
                t.name == "dist-chief-conn" for t in threading.enumerate()):
            break
        time.sleep(0.02)
    assert store.exits == 1


def test_chief_close_is_clean_after_bye():
    from repro.dist import protocol
    from repro.dist.chief import Chief

    chief = Chief(_StubStore(), {"n_workers": 1})
    conn = protocol.connect(chief.address)
    conn.send(("hello", 0))
    conn.recv()
    conn.send(("bye", 0))
    conn.close()
    chief.close(timeout=5.0, strict=True)    # strict: a leak would raise
    assert chief.leaked_threads == []


def test_connect_backoff_reports_attempts_and_elapsed():
    import socket

    from repro.dist import protocol

    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()                                # nothing listens here any more
    t0 = time.monotonic()
    with pytest.raises(ConnectionError, match=r"attempts over .*s \(last"):
        protocol.connect(("127.0.0.1", port), timeout=0.4)
    assert time.monotonic() - t0 >= 0.35     # it really retried to deadline
