"""Optimizer math and schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adagrad, adam, constant, cosine, get_optimizer, momentum, rmsprop, sgd, wsd


def _tree():
    return {"a": jnp.asarray([1.0, -2.0]), "b": jnp.asarray([[0.5]])}


def _grad():
    return {"a": jnp.asarray([0.1, 0.2]), "b": jnp.asarray([[-0.3]])}


def test_sgd_update():
    opt = sgd()
    s = opt.init(_tree())
    upd, s = opt.update(_grad(), s, _tree(), 0.5)
    np.testing.assert_allclose(np.asarray(upd["a"]), [-0.05, -0.1], atol=1e-7)


def test_rmsprop_matches_paper_formula():
    """Paper Fig. 11: r = beta r + (1-beta) v^2; W -= eta v / sqrt(r + eps)."""
    opt = rmsprop(beta=0.9, eps=1e-8)
    p, g = _tree(), _grad()
    s = opt.init(p)
    upd, s = opt.update(g, s, p, 0.2)
    r = 0.1 * np.asarray(g["a"]) ** 2
    expect = -0.2 * np.asarray(g["a"]) / np.sqrt(r + 1e-8)
    np.testing.assert_allclose(np.asarray(upd["a"]), expect, rtol=1e-6)


def test_adagrad_accumulates():
    opt = adagrad()
    p, g = _tree(), _grad()
    s = opt.init(p)
    _, s = opt.update(g, s, p, 0.1)
    _, s = opt.update(g, s, p, 0.1)
    np.testing.assert_allclose(np.asarray(s["r"]["a"]), 2 * np.asarray(g["a"]) ** 2, rtol=1e-6)


def test_adam_bias_correction_first_step():
    opt = adam(b1=0.9, b2=0.999)
    p, g = _tree(), _grad()
    s = opt.init(p)
    upd, s = opt.update(g, s, p, 1e-3)
    # after bias correction the first step is ~ -lr * sign(g)
    np.testing.assert_allclose(np.asarray(upd["a"]), -1e-3 * np.sign(g["a"]), rtol=1e-3)


def test_momentum_accumulates_direction():
    opt = momentum(beta=0.9)
    p, g = _tree(), _grad()
    s = opt.init(p)
    upd1, s = opt.update(g, s, p, 0.1)
    upd2, s = opt.update(g, s, p, 0.1)
    assert abs(float(upd2["a"][0])) > abs(float(upd1["a"][0]))


def test_schedules():
    assert float(constant(0.2)(100)) == pytest.approx(0.2)
    c = cosine(1.0, warmup=10, total=110)
    assert float(c(0)) == pytest.approx(0.0)
    assert float(c(10)) == pytest.approx(1.0, abs=1e-3)
    assert float(c(110)) == pytest.approx(0.1, abs=1e-3)
    w = wsd(1.0, warmup=10, stable=50, decay=40)
    assert float(w(5)) == pytest.approx(0.5)
    assert float(w(30)) == pytest.approx(1.0)
    assert float(w(100)) == pytest.approx(0.01, abs=1e-3)
    assert float(w(45)) == pytest.approx(1.0)  # still in stable phase


def test_registry():
    for name in ("sgd", "momentum", "rmsprop", "adagrad", "adam"):
        assert get_optimizer(name).name == name
