"""End-to-end driver: train a ~100M-parameter decoder for a few hundred steps
with guided synchronous SGD on the synthetic Markov LM stream.

This wraps the production launcher (repro.launch.train) with a 100M config
derived from minicpm-2b (same family, fewer layers). On a TPU mesh pass
--mesh prod; on this CPU host expect a few seconds per step at the default
sizes — use --steps/--d-model to trade fidelity for time.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse
import sys

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--d-model", type=int, default=576)
ap.add_argument("--layers", type=int, default=12)
ap.add_argument("--mesh", default="local")
args = ap.parse_args()

# minicpm-2b family at ~135M: 12 layers x d_model 576, d_ff 2304 + tied 122k-vocab embed
argv = [
    "--arch", "minicpm-2b",
    "--layers", str(args.layers), "--d-model", str(args.d_model), "--d-ff", "2304",
    "--steps", str(args.steps), "--seq", str(args.seq), "--batch", str(args.batch),
    "--mode", "ssgd", "--strategy", "guided_fused", "--rho", "10", "--workers", "4",
    "--optimizer", "sgd", "--lr", "0.05", "--schedule", "wsd",
    "--mesh", args.mesh, "--log-every", "10",
    "--ckpt-dir", "results/ckpt_100m", "--ckpt-every", "100",
    "--metrics-out", "results/train_100m.json",
]
history = train_main(argv)
first, last = history[0]["loss"], history[-1]["loss"]
print(f"\ntrained: loss {first:.3f} -> {last:.3f} "
      f"({'DECREASED' if last < first else 'check hyperparams'})")
