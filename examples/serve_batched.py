"""Serve a small model with a batch of requests: prefill + autoregressive
decode against ring-buffer KV caches (or recurrent state for SSM archs).

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch xlstm-350m]
"""
import argparse

from repro.launch.serve import main as serve_main

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="xlstm-350m")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=64)
ap.add_argument("--gen", type=int, default=16)
args = ap.parse_args()

serve_main([
    "--arch", args.arch, "--reduced",
    "--batch", str(args.batch),
    "--prompt-len", str(args.prompt_len),
    "--gen", str(args.gen),
])
