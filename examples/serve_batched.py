"""Continuous-batching serving example: drive the ServeEngine API directly
with streamed tokens.

Submits requests with heterogeneous prompt/generation lengths to a slot pool
smaller than the request count, so admission, per-slot decode positions and
slot recycling are all exercised; an `on_token` callback streams tokens as
they are accepted (and is asserted to match the final completions). For the
CLI client — including the barriered --lockstep baseline and the full
sampling flags — use `python -m repro.launch.serve`.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch xlstm-350m]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.module import split_params
from repro.serve import Request, SamplingParams, ServeEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="xlstm-350m")
ap.add_argument("--batch", type=int, default=4, help="engine slot-pool size")
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--gen", type=int, default=16)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
params = split_params(T.model_init(jax.random.PRNGKey(0), cfg))[0]
engine = ServeEngine(params, cfg, max_batch=args.batch,
                     max_len=args.prompt_len + args.gen)

rng = np.random.default_rng(0)
streams: dict = {}


def on_token(req_id, tok):
    streams.setdefault(req_id, []).append(tok)


reqs = []
for i in range(args.requests):
    L = int(rng.integers(max(1, args.prompt_len // 4), args.prompt_len + 1))
    gen = int(rng.integers(max(1, args.gen // 4), args.gen + 1))
    reqs.append(Request(
        rng.integers(0, cfg.vocab_size, (L,)).tolist(), max_new_tokens=gen,
        sampling=SamplingParams(method="topk", temperature=0.8, top_k=40, seed=i),
        on_token=on_token))

comps = engine.run(reqs)
stats = engine.stats()

for c in sorted(comps, key=lambda c: c.request_id):
    assert streams[c.request_id] == c.tokens  # streaming == completion
    print(f"request {c.request_id}: prompt {c.prompt_len:3d} -> "
          f"{c.new_tokens:2d} tokens ({c.finish_reason}, slot {c.slot}): "
          f"{c.tokens[:8]}{'...' if c.new_tokens > 8 else ''}")
print(f"decode: {stats['decode_steps']} steps, {stats['tokens_per_s']:.1f} tok/s, "
      f"occupancy {stats['occupancy']:.2f}")
