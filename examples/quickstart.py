"""Quickstart: train a reduced assigned architecture with guided SSGD.

Shows the three moving parts of the framework in ~40 lines:
  1. a model from the architecture registry (reduced for CPU),
  2. the guided delay-compensated optimizer (the paper's contribution),
  3. the jitted train step with per-worker consistency tracking.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.guided import GuidedConfig
from repro.data import make_batch_for
from repro.optim import constant, get_optimizer
from repro.sharding.rules import LOCAL_CTX
from repro.train import steps as S

ARCH = "yi-9b"          # any of the 10 assigned archs
C_WORKERS = 4           # the paper's c (= data-parallel workers on a real mesh)

cfg = get_config(ARCH).reduced()
gcfg = GuidedConfig(mode="ssgd", guided=True, rho=5)   # gSSGD, paper defaults
opt = get_optimizer("sgd")

params, logical, gstate = S.make_train_state(
    jax.random.PRNGKey(0), cfg, gcfg, opt, n_workers=C_WORKERS
)
train_step = jax.jit(
    S.build_train_step(cfg, gcfg, opt, LOCAL_CTX, constant(1e-2), n_workers=C_WORKERS)
)

batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, seq_len=32, global_batch=8).items()}
for step in range(20):
    params, gstate, metrics = train_step(params, gstate, batch)
    if step % 5 == 0 or float(metrics["corr_weight_sum"]) > 0:
        print(
            f"step {step:3d} loss={float(metrics['loss']):.4f} "
            f"worker_var={float(metrics['worker_loss_var']):.2e} "
            f"guided_correction={'FIRED' if float(metrics['corr_weight_sum']) > 0 else '-'}"
        )
print("scores per worker:", [round(float(s), 2) for s in gstate.score])
