"""Quickstart: train a reduced assigned architecture with guided SSGD.

Shows the three moving parts of the framework in ~30 lines:
  1. an ExperimentSpec naming the experiment (arch, mode, strategy),
  2. the DelayCompensator strategy registry (the paper's contribution is
     `guided_fused`; swap the string for `dc_asgd`, `gap_aware`, ...),
  3. the Trainer facade running the jitted train step with per-worker
     consistency tracking.

This demo uses the mesh (transformer) backend. The same spec vocabulary runs
the paper-scale simulators: `backend="scan"` is the jitted delay simulator
(multi-seed sweeps via `n_seeds`, delay topologies via `topology`; the
benchmarks accept `--backend scan|sim`), `backend="sim"` the numpy reference.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.engine import ExperimentSpec, Trainer

spec = ExperimentSpec(
    backend="mesh",
    arch="yi_9b",            # any of the 10 assigned archs
    reduced=True,
    mode="ssgd",
    strategy="guided_fused",  # gSSGD, paper defaults
    rho=5,
    workers=4,               # the paper's c (= data-parallel workers on a real mesh)
    lr=1e-2,
    steps=20,
    seq_len=32,
    global_batch=8,
)


def on_step(step, m, params):
    corr_w = float(m["corr_weight_sum"])
    if step % 5 == 0 or corr_w > 0:
        print(
            f"step {step:3d} loss={float(m['loss']):.4f} "
            f"worker_var={float(m['worker_loss_var']):.2e} "
            f"guided_correction={'FIRED' if corr_w > 0 else '-'}"
        )


report = Trainer.from_spec(spec).fit(on_step=on_step)
print("scores per worker:", [round(float(s), 2) for s in report.state.score])
