"""The paper's core experiment in miniature (Tables 2-3): compare sequential,
synchronous and asynchronous SGD with and without the guided delay
compensation, on two of the UCI-analog datasets.

Run:  PYTHONPATH=src python examples/parallel_sgd_comparison.py
"""
import numpy as np

from repro.core.parameter_server import algo_config, train_ps
from repro.data import load_dataset, train_test_split

ALGOS = ["SGD", "gSGD", "SSGD", "gSSGD", "ASGD", "gASGD"]
RUNS, EPOCHS = 8, 50

for ds in ("new_thyroid", "breast_cancer_diagnostic"):
    X, y, k = load_dataset(ds, seed=0)
    print(f"\n=== {ds} (n={len(X)}, d={X.shape[1]}, classes={k}) ===")
    for algo in ALGOS:
        accs = []
        for run in range(RUNS):
            Xtr, ytr, Xte, yte = train_test_split(X, y, seed=run)
            res = train_ps(Xtr, ytr, k, algo_config(algo, epochs=EPOCHS, seed=run), Xte, yte)
            accs.append(res["test_accuracy"] * 100)
        print(f"  {algo:8s} acc = {np.mean(accs):5.1f} ± {np.std(accs):4.1f}")
print("\nExpected pattern (paper): SSGD/ASGD < SGD (delay hurts); "
      "gSSGD recovers much of the gap; gSGD >= SGD.")
