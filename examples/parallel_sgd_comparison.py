"""The paper's core experiment in miniature (Tables 2-3): compare sequential,
synchronous and asynchronous SGD with and without the guided delay
compensation, on two of the UCI-analog datasets — driven entirely through the
unified engine API (`ExperimentSpec.for_algo` + `Trainer`).

Run:  PYTHONPATH=src python examples/parallel_sgd_comparison.py
"""
import numpy as np

from repro.data import load_dataset, train_test_split
from repro.engine import ExperimentSpec, Trainer

ALGOS = ["SGD", "gSGD", "SSGD", "gSSGD", "ASGD", "gASGD"]
RUNS, EPOCHS = 8, 50

for ds in ("new_thyroid", "breast_cancer_diagnostic"):
    X, y, k = load_dataset(ds, seed=0)
    print(f"\n=== {ds} (n={len(X)}, d={X.shape[1]}, classes={k}) ===")
    for algo in ALGOS:
        accs = []
        for run in range(RUNS):
            Xtr, ytr, Xte, yte = train_test_split(X, y, seed=run)
            spec = ExperimentSpec.for_algo(algo, epochs=EPOCHS, seed=run)
            report = Trainer.from_spec(spec).fit((Xtr, ytr, k, Xte, yte))
            accs.append(report.test_accuracy * 100)
        print(f"  {algo:8s} acc = {np.mean(accs):5.1f} ± {np.std(accs):4.1f}")
print("\nExpected pattern (paper): SSGD/ASGD < SGD (delay hurts); "
      "gSSGD recovers much of the gap; gSGD >= SGD.")
